"""Pallas-TPU kernel: 2-D histogram via one-hot matmuls on the MXU.

Scatter-adds serialize on TPU; instead each grid step turns a tile of TN
rows into two one-hot matrices and accumulates

    H += one_hot(bi_tile)^T  @  (one_hot(bj_tile) * w_tile)

— a (KI x TN) @ (TN x KJ) systolic matmul. The full (KI, KJ) accumulator
lives in VMEM across grid steps (KI, KJ <= 512 -> <= 1 MiB f32); row tiles
stream HBM -> VMEM via BlockSpec.

This is the TPU adaptation of PairwiseHist construction's hot spot (DESIGN.md
§3): bin counting for d(d-1)/2 pair histograms over N_s sampled rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(bi_ref, bj_ref, w_ref, out_ref, *, ki: int, kj: int, tn: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bi = bi_ref[...]                                   # (TN,) i32
    bj = bj_ref[...]
    w = w_ref[...].astype(jnp.float32)                 # (TN,)
    rows_i = jax.lax.broadcasted_iota(jnp.int32, (tn, ki), 1)
    rows_j = jax.lax.broadcasted_iota(jnp.int32, (tn, kj), 1)
    oh_i = (rows_i == bi[:, None]).astype(jnp.float32)             # (TN, KI)
    oh_j = (rows_j == bj[:, None]).astype(jnp.float32) * w[:, None]
    out_ref[...] += jax.lax.dot_general(
        oh_i, oh_j, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (KI, KJ)


@functools.partial(jax.jit, static_argnames=("ki", "kj", "tn", "interpret"))
def hist2d_pallas(bi, bj, weights, ki: int, kj: int, tn: int = 1024,
                  interpret: bool = True):
    """bi/bj: (N,) int32 (N % tn == 0; pad with weight-0 rows), w: (N,)."""
    n = bi.shape[0]
    assert n % tn == 0, "pad N to a multiple of the row tile in ops.py"
    grid = (n // tn,)
    return pl.pallas_call(
        functools.partial(_kernel, ki=ki, kj=kj, tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ki, kj), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ki, kj), jnp.float32),
        interpret=interpret,
    )(bi, bj, weights)


def _batched_kernel(bi_ref, bj_ref, w_ref, out_ref, *, ki: int, kj: int,
                    tn: int):
    """One grid step = (pair p, row tile t): accumulate into pair p's plane."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bi = bi_ref[0]                                     # (TN,) i32
    bj = bj_ref[0]
    w = w_ref[0].astype(jnp.float32)
    rows_i = jax.lax.broadcasted_iota(jnp.int32, (tn, ki), 1)
    rows_j = jax.lax.broadcasted_iota(jnp.int32, (tn, kj), 1)
    oh_i = (rows_i == bi[:, None]).astype(jnp.float32)             # (TN, KI)
    oh_j = (rows_j == bj[:, None]).astype(jnp.float32) * w[:, None]
    out_ref[0] += jax.lax.dot_general(
        oh_i, oh_j, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (KI, KJ)


@functools.partial(jax.jit, static_argnames=("ki", "kj", "tn", "interpret"))
def batched_hist2d_pallas(bi, bj, weights, ki: int, kj: int, tn: int = 1024,
                          interpret: bool = True):
    """Pair-batched 2-D histogram: (P, N) indices/weights -> (P, KI, KJ).

    The grid is (P, N // tn); each pair's accumulator plane lives in VMEM
    across its row tiles (tiles are the innermost grid dimension, so a
    pair's steps are contiguous and the revisited output block stays
    resident). Rows with out-of-histogram indices must carry weight 0.
    """
    p, n = bi.shape
    assert n % tn == 0, "pad N to a multiple of the row tile in ops.py"
    grid = (p, n // tn)
    return pl.pallas_call(
        functools.partial(_batched_kernel, ki=ki, kj=kj, tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
        ],
        out_specs=pl.BlockSpec((1, ki, kj), lambda pi, ti: (pi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, ki, kj), jnp.float32),
        interpret=interpret,
    )(bi, bj, weights)
