"""Jitted wrapper: padding, MXU-friendly K alignment, backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hist2d.hist2d import batched_hist2d_pallas, hist2d_pallas
from repro.kernels.hist2d.ref import batched_hist2d_ref, hist2d_ref


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def hist2d(bi, bj, weights, ki: int, kj: int, *, use_pallas: bool = True,
           interpret: bool | None = None, tn: int = 1024):
    """Weighted 2-D histogram (KI, KJ) from per-point bin indices.

    On TPU the Pallas kernel runs compiled; on CPU it runs in interpret mode
    (the kernel body executed in Python — correctness path). K dims are
    padded to multiples of 128 (MXU lanes), N to the row tile.
    """
    bi = jnp.asarray(bi, jnp.int32)
    bj = jnp.asarray(bj, jnp.int32)
    weights = jnp.asarray(weights, jnp.float32)
    if not use_pallas:
        return hist2d_ref(bi, bj, weights, ki, kj)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = bi.shape[0]
    n_pad = _round_up(max(n, tn), tn)
    ki_pad = _round_up(ki, 128)
    kj_pad = _round_up(kj, 128)
    if n_pad != n:
        pad = n_pad - n
        bi = jnp.pad(bi, (0, pad))
        bj = jnp.pad(bj, (0, pad))
        weights = jnp.pad(weights, (0, pad))  # zero weight => no contribution
    out = hist2d_pallas(bi, bj, weights, ki_pad, kj_pad, tn=tn,
                        interpret=bool(interpret))
    return out[:ki, :kj]


def batched_hist2d(bi, bj, weights, ki: int, kj: int, *,
                   use_pallas: bool = True, interpret: bool | None = None,
                   tn: int = 1024):
    """Pair-batched weighted 2-D histograms: (P, N) -> (P, KI, KJ).

    This is the construction hot loop's inner op (one call per refinement
    round bins *every* pair), mirroring ``weightings.batched_weightings``:
    jnp oracle (dtype-preserving scatter-add) vs Pallas one-hot-matmul
    kernel with K dims padded to 128 lanes and N padded to the row tile.
    Padding is value-safe: padded rows carry weight 0 and padded K
    rows/columns are sliced away. Traceable under jit (static shapes).

    Power-of-two bucketing contract: the batch dimension P is fixed by the
    caller's chunking — ``BuildParams.pair_chunk`` rounds DOWN to a power
    of two (the chunk is a ``pair_chunk * k2^2 * s2_max`` memory *ceiling*,
    so bucketing must never exceed it), and the final partial chunk of a
    build buckets its launch size likewise, so jit recompiles stay bounded
    at ``log2(pair_chunk)`` variants per K shape. Compare
    ``weightings.ops.q_bucket``, the serving-side analogue, which buckets
    UP (padding there is cheaper than a lost fusion opportunity).
    """
    bi = jnp.asarray(bi, jnp.int32)
    bj = jnp.asarray(bj, jnp.int32)
    weights = jnp.asarray(weights)
    if not use_pallas:
        return batched_hist2d_ref(bi, bj, weights, ki, kj)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    p, n = bi.shape
    n_pad = _round_up(max(n, tn), tn)
    ki_pad = _round_up(ki, 128)
    kj_pad = _round_up(kj, 128)
    if n_pad != n:
        pad = ((0, 0), (0, n_pad - n))
        bi = jnp.pad(bi, pad)
        bj = jnp.pad(bj, pad)
        weights = jnp.pad(weights, pad)  # zero weight => no contribution
    out = batched_hist2d_pallas(bi, bj, weights.astype(jnp.float32),
                                ki_pad, kj_pad, tn=tn,
                                interpret=bool(interpret))
    return out[:, :ki, :kj].astype(weights.dtype)


def hist2d_sharded(bi, bj, weights, ki: int, kj: int, mesh,
                   axis: str = "data", use_pallas: bool | None = None):
    """Row-sharded distributed bin counting (DESIGN.md §3.5).

    Rows shard across the mesh's ``axis``; each device bins its shard and
    the (ki, kj) count matrix reduces via the psum GSPMD inserts for the
    replicated output. This is the pod-scale construction path: refinement
    decisions depend only on these counts, so only counts ever cross chips.

    Binning routes through ``batched_hist2d`` (as a P=1 batch), the same
    dispatch the pair-batched construction loop uses — one kernel to
    validate and tune for both scales. ``use_pallas=None`` resolves by
    backend (Pallas on TPU, jnp oracle elsewhere — off-TPU interpret mode
    is a correctness path, not a speed path, and the oracle needs no row
    padding, which under GSPMD would force a reshard).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    row_sharding = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    bi = jax.device_put(jnp.asarray(bi, jnp.int32), row_sharding)
    bj = jax.device_put(jnp.asarray(bj, jnp.int32), row_sharding)
    weights = jax.device_put(jnp.asarray(weights, jnp.float32), row_sharding)
    fn = jax.jit(lambda a, b, w: batched_hist2d(
        a[None], b[None], w[None], ki, kj, use_pallas=use_pallas)[0],
        out_shardings=rep)
    return fn(bi, bj, weights)
