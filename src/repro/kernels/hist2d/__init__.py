"""2-D bin-counting kernels (construction hot spot): single-pair and
pair-batched variants, each with a Pallas one-hot-matmul kernel and a
scatter-add jnp oracle. See ``ops.py`` for the padding and power-of-two
bucketing contracts."""
from repro.kernels.hist2d.ops import batched_hist2d, hist2d  # noqa: F401
