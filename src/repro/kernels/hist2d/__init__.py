from repro.kernels.hist2d.ops import hist2d  # noqa: F401
