from repro.kernels.hist2d.ops import batched_hist2d, hist2d  # noqa: F401
