"""Pure-jnp oracles for the hist2d kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hist2d_ref(bi, bj, weights, ki: int, kj: int):
    """Weighted 2-D histogram: H[a, b] = sum_n w_n [bi_n == a][bj_n == b].

    bi/bj: (N,) int32 bin indices (out-of-range rows must carry weight 0).
    weights: (N,) float32.
    """
    h = jnp.zeros((ki, kj), jnp.float32)
    bi = jnp.clip(bi, 0, ki - 1)
    bj = jnp.clip(bj, 0, kj - 1)
    return h.at[bi, bj].add(weights.astype(jnp.float32))


def batched_hist2d_ref(bi, bj, weights, ki: int, kj: int):
    """Pair-batched oracle: (P, N) indices/weights -> (P, KI, KJ).

    Unlike ``hist2d_ref`` this *preserves the weight dtype*: synopsis
    construction feeds f64 ones/flags and compares counts bit-for-bit
    against the sequential per-pair ``segment_sum`` path (counts are exact
    integers, so the f32 Pallas path agrees too for N < 2^24).
    """
    def one(bi_p, bj_p, w_p):
        h = jnp.zeros((ki, kj), weights.dtype)
        return h.at[jnp.clip(bi_p, 0, ki - 1), jnp.clip(bj_p, 0, kj - 1)].add(w_p)

    return jax.vmap(one)(bi, bj, weights)
