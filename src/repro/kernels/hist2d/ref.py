"""Pure-jnp oracle for the hist2d kernel."""
from __future__ import annotations

import jax.numpy as jnp


def hist2d_ref(bi, bj, weights, ki: int, kj: int):
    """Weighted 2-D histogram: H[a, b] = sum_n w_n [bi_n == a][bj_n == b].

    bi/bj: (N,) int32 bin indices (out-of-range rows must carry weight 0).
    weights: (N,) float32.
    """
    h = jnp.zeros((ki, kj), jnp.float32)
    bi = jnp.clip(bi, 0, ki - 1)
    bj = jnp.clip(bj, 0, kj - 1)
    return h.at[bi, bj].add(weights.astype(jnp.float32))
