"""Pallas-TPU kernel: sub-bin histograms via one-hot matmuls on the MXU.

The chi-squared uniformity test bins every point of every 2-D cell into one
of ``s <= s_max`` equal-width sub-bins — a histogram over ``ncell * s_max``
flattened (cell, sub-bin) ids, recomputed every refinement round. On TPU a
``segment_sum`` scatter over that id space serializes; instead the flat id
is decomposed base-128 as ``flat = q * 128 + r`` and each grid step turns a
tile of TN rows into two one-hot matrices and accumulates

    H += one_hot(q_tile)^T  @  (one_hot(r_tile) * w_tile)

— a (KQ x TN) @ (TN x 128) systolic matmul whose 128-lane minor dimension
is exactly the MXU lane width (no padding waste on the one-hot columns).
The (KQ, 128) accumulator lives in VMEM across a pair's row tiles; KQ =
ncell * s_max / 128, so the accumulator is ``ncell * s_max * 4`` bytes —
512 KiB at the default ladder rung (k2 = 64, s_max = 32). The caller keeps
capacity rungs small (``ops.py``); the k2 = 256 ceiling would need 8 MiB,
which still fits VMEM but leaves no headroom for double buffering.

This mirrors ``kernels/hist2d``: same grid layout, same padding contract
(rows padded to the tile carry weight 0), same f32 accumulation (counts are
exact integers below 2^24).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _batched_kernel(q_ref, r_ref, w_ref, out_ref, *, kq: int, tn: int):
    """One grid step = (pair p, row tile t): accumulate into pair p's plane."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[0]                                       # (TN,) i32
    r = r_ref[0]
    w = w_ref[0].astype(jnp.float32)
    rows_q = jax.lax.broadcasted_iota(jnp.int32, (tn, kq), 1)
    rows_r = jax.lax.broadcasted_iota(jnp.int32, (tn, 128), 1)
    oh_q = (rows_q == q[:, None]).astype(jnp.float32)              # (TN, KQ)
    oh_r = (rows_r == r[:, None]).astype(jnp.float32) * w[:, None]
    out_ref[0] += jax.lax.dot_general(
        oh_q, oh_r, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (KQ, 128)


@functools.partial(jax.jit, static_argnames=("kq", "tn", "interpret"))
def batched_subbin_hist_pallas(q, r, weights, kq: int, tn: int = 1024,
                               interpret: bool = True):
    """Pair-batched flat-id histogram: (P, N) -> (P, KQ, 128).

    ``q``/``r`` are the base-128 digits of the flattened (cell, sub-bin) id
    (``ops.py`` computes them); rows with out-of-histogram ids must carry
    weight 0. The grid is (P, N // tn) with tiles innermost, so each pair's
    accumulator plane stays VMEM-resident across its row tiles.
    """
    p, n = q.shape
    assert n % tn == 0, "pad N to a multiple of the row tile in ops.py"
    grid = (p, n // tn)
    return pl.pallas_call(
        functools.partial(_batched_kernel, kq=kq, tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
            pl.BlockSpec((1, tn), lambda pi, ti: (pi, ti)),
        ],
        out_specs=pl.BlockSpec((1, kq, 128), lambda pi, ti: (pi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, kq, 128), jnp.float32),
        interpret=interpret,
    )(q, r, weights)
