"""Jitted wrapper: flat-id decomposition, padding, backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.subbin.ref import batched_subbin_hist_ref
from repro.kernels.subbin.subbin import batched_subbin_hist_pallas


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def batched_subbin_hist(cell, sub, weights, ncell: int, s_max: int, *,
                        use_pallas: bool = True,
                        interpret: bool | None = None, tn: int = 1024):
    """Pair-batched sub-bin histograms: (P, N) -> (P, ncell, s_max).

    This is the chi-squared inner scatter of 2-D refinement (the one
    remaining per-round scatter after the bin counts moved to
    ``hist2d.batched_hist2d``): every valid point of pair ``p`` adds its
    weight to ``out[p, cell, sub]``. Rows that must not contribute (null
    rows, padding) carry weight 0; indices are clipped, never trusted.

    Dispatch mirrors ``hist2d.batched_hist2d``: a dtype-preserving
    ``segment_sum`` jnp oracle (bit-for-bit against the legacy in-loop
    scatter — construction compares exact integer counts) vs the Pallas
    one-hot-matmul kernel. For the kernel the flattened id
    ``cell * s_max + sub`` is decomposed base-128 (``q = id // 128``,
    ``r = id % 128``) so the one-hot minor dimension is exactly the MXU
    lane width; the (KQ, 128) planes are sliced back to (ncell, s_max).
    N pads to the row tile with weight-0 rows; the batch dimension P
    follows the caller's power-of-two bucketing contract (see
    ``hist2d/ops.py``).
    """
    cell = jnp.asarray(cell, jnp.int32)
    sub = jnp.asarray(sub, jnp.int32)
    weights = jnp.asarray(weights)
    if not use_pallas:
        return batched_subbin_hist_ref(cell, sub, weights, ncell, s_max)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    p, n = cell.shape
    k = ncell * s_max
    kq = _round_up(-(-k // 128), 8)       # ceil(k/128), sublane-aligned
    flat = (jnp.clip(cell, 0, ncell - 1) * s_max
            + jnp.clip(sub, 0, s_max - 1))
    q = flat // 128
    r = flat % 128
    n_pad = _round_up(max(n, tn), tn)
    w = weights.astype(jnp.float32)
    if n_pad != n:
        pad = ((0, 0), (0, n_pad - n))
        q = jnp.pad(q, pad)
        r = jnp.pad(r, pad)
        w = jnp.pad(w, pad)               # zero weight => no contribution
    out = batched_subbin_hist_pallas(q, r, w, kq, tn=tn,
                                     interpret=bool(interpret))
    out = out.reshape(p, kq * 128)[:, :k].reshape(p, ncell, s_max)
    return out.astype(weights.dtype)
