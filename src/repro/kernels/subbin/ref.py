"""Pure-jnp oracle for the sub-bin histogram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batched_subbin_hist_ref(cell, sub, weights, ncell: int, s_max: int):
    """Pair-batched sub-bin histogram: (P, N) -> (P, ncell, s_max).

    ``hbar[p, c, r] = sum_n w[p, n] [cell[p, n] == c][sub[p, n] == r]``.
    Rows that must not contribute carry weight 0 (indices are clipped, so
    out-of-range ids land somewhere but add nothing).

    Like ``hist2d.batched_hist2d_ref`` this *preserves the weight dtype*:
    2-D refinement feeds f64 validity ones and the chi-squared statistic is
    compared bit-for-bit against the sequential ``segment_sum`` path —
    counts are exact integers, so the f32 Pallas path agrees too for
    N < 2^24.
    """
    p = cell.shape[0]
    flat = (jnp.clip(cell, 0, ncell - 1) * s_max
            + jnp.clip(sub, 0, s_max - 1))
    hbar = jax.vmap(lambda f, w: jax.ops.segment_sum(
        w, f, num_segments=ncell * s_max))(flat, weights)
    return hbar.reshape(p, ncell, s_max)
