"""Sub-bin histogram kernel (the chi-squared inner scatter of 2-D
refinement): pair-batched, with a Pallas one-hot-matmul kernel and a
dtype-preserving segment-sum jnp oracle. See ``ops.py`` for the flat-id
decomposition and padding contracts."""
from repro.kernels.subbin.ops import batched_subbin_hist  # noqa: F401
