"""Fused weightings kernels (query hot spot): single-query and
query-batched variants, each with a Pallas kernel and a jnp oracle. See
``ops.py`` for the padding and the ``q_bucket`` power-of-two bucketing
contract shared with the serving batch scheduler."""
from repro.kernels.weightings.ops import (batched_weightings,  # noqa: F401
                                          fused_weightings, q_bucket)
