from repro.kernels.weightings.ops import (batched_weightings,  # noqa: F401
                                          fused_weightings)
