from repro.kernels.weightings.ops import fused_weightings  # noqa: F401
