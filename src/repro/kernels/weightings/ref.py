"""Pure-jnp oracle for the fused weightings kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_weightings_ref(h_stack, beta, fold, hx):
    """prod_l fold_l( clip( (H_l @ beta_l) / hx_l , 0, 1) )  — Eq. 25/27/28.

    h_stack: (L, K2, K2)  padded pair-count matrices (x-dim = agg column)
    beta:    (L, K2)      coverage vectors on the predicate columns' slices
    fold:    (L, K1, K2)  one-hot gather: 1-D bin -> containing pair x-row
    hx:      (L, K2)      pair x-row totals
    Returns  (K1,) per-1-D-bin probability product; the caller multiplies by
    the 1-D bin counts h^(i) to obtain weightings (Eq. 24).
    """
    v = jnp.einsum("lab,lb->la", h_stack, beta)          # (L, K2)
    p_row = jnp.clip(v / jnp.maximum(hx, 1e-30), 0.0, 1.0)
    p1 = jnp.einsum("lka,la->lk", fold, p_row)           # (L, K1)
    return jnp.prod(p1, axis=0)


def batched_weightings_ref(h_stack, beta, fold, hx):
    """Query-batched fused weightings — Eq. 25/27/28 over Q queries at once.

    The (H, fold, hx) stacks depend only on the (agg column, predicate
    columns) plan shape, so a group of queries sharing that shape shares
    them; only beta varies per query.

    h_stack: (L, K2, K2)   shared pair-count matrices
    beta:    (Q, L, K2)    per-query coverage vectors
    fold:    (L, K1, K2)   shared one-hot gathers
    hx:      (L, K2)       shared pair x-row totals
    Returns  (Q, K1) per-query probability products.
    """
    v = jnp.einsum("lab,qlb->qla", h_stack, beta)            # (Q, L, K2)
    p_row = jnp.clip(v / jnp.maximum(hx, 1e-30)[None], 0.0, 1.0)
    p1 = jnp.einsum("lka,qla->qlk", fold, p_row)             # (Q, L, K1)
    return jnp.prod(p1, axis=1)
