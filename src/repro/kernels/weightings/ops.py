"""Jitted wrapper for the fused weightings kernel: pad + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.weightings.ref import (batched_weightings_ref,
                                          fused_weightings_ref)
from repro.kernels.weightings.weightings import (batched_weightings_pallas,
                                                 fused_weightings_pallas)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def q_bucket(q: int) -> int:
    """Power-of-two bucketing contract for the query-batch dimension.

    Serving waves produce arbitrary (ragged) group sizes — any mix of plain
    queries and GROUP BY leaf fan-outs — and a jit recompile per distinct Q
    would dwarf the dispatch being amortized. Launch sizes therefore bucket
    UP to the next power of two, with a floor of 8 (below which padding is
    cheaper than another compiled variant): at most ``log2(max_group) - 2``
    compiled variants ever exist per (L, K1, K2) shape. Padded query rows
    are value-safe garbage and are sliced away by the caller.

    The construction-side analogue is ``BuildParams.pair_chunk`` for
    ``kernels.hist2d.batched_hist2d``, which buckets DOWN (see there: the
    chunk bound is a memory ceiling, not a floor).
    """
    return max(8, 1 << (int(q) - 1).bit_length())


_ref_jit = jax.jit(fused_weightings_ref)
_batched_ref_jit = jax.jit(batched_weightings_ref)


def fused_weightings(h_stack, beta, fold, hx, *, use_pallas: bool = True,
                     interpret: bool | None = None):
    """See ref.py for semantics. Pads K1/K2 to 128 multiples for the MXU.

    Padding is value-safe: padded H rows/cols and beta/hx entries are zero
    => p_row pads to 0; padded fold rows are zero => p1 pads to 0 and those
    1-D bins are sliced away.
    """
    h_stack = jnp.asarray(h_stack, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    fold = jnp.asarray(fold, jnp.float32)
    hx = jnp.asarray(hx, jnp.float32)
    if not use_pallas:
        return _ref_jit(h_stack, beta, fold, hx)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    el, k2, _ = h_stack.shape
    k1 = fold.shape[1]
    k2p = _round_up(k2, 128)
    k1p = _round_up(k1, 128)
    if (k2p, k1p) != (k2, k1):
        h_stack = jnp.pad(h_stack, ((0, 0), (0, k2p - k2), (0, k2p - k2)))
        beta = jnp.pad(beta, ((0, 0), (0, k2p - k2)))
        hx = jnp.pad(hx, ((0, 0), (0, k2p - k2)))
        fold = jnp.pad(fold, ((0, 0), (0, k1p - k1), (0, k2p - k2)))
    out = fused_weightings_pallas(h_stack, beta, fold, hx,
                                  interpret=bool(interpret))
    return out[:k1]


def batched_weightings(h_stack, beta, fold, hx, *, use_pallas: bool = True,
                       interpret: bool | None = None):
    """Query-batched fused weightings: beta (Q, L, K2) -> (Q, K1).

    See ref.batched_weightings_ref for semantics. Q is bucketed to a power
    of two (``q_bucket``: UP to the next pow-2, min 8) so ragged serving
    group sizes — plain queries and GROUP BY leaf fan-outs alike — reuse a
    bounded set of compiled launch variants; K1/K2 pad to 128-lane
    multiples. Padding is value-safe: padded beta rows produce garbage rows
    that are sliced away; padded K entries are zero.

    ``beta`` is per-wave host data and is padded in NumPy (one device
    transfer, no dispatched pad ops on the hot path); the shared
    h/fold/hx stacks should already be device-resident and 128-padded
    (``FastPath._get_stack``) — if not, they are padded here once.
    """
    beta = np.asarray(beta, np.float32)
    q, el, k2 = beta.shape
    k1 = fold.shape[1]
    qp = q_bucket(q)
    k2p = _round_up(k2, 128)
    k1p = _round_up(k1, 128)
    if use_pallas and interpret is None:
        interpret = jax.default_backend() != "tpu"

    h_stack = jnp.asarray(h_stack, jnp.float32)
    fold = jnp.asarray(fold, jnp.float32)
    hx = jnp.asarray(hx, jnp.float32)
    pad_k = (k2p, k1p) != (k2, k1) and use_pallas
    if pad_k:
        h_stack = jnp.pad(h_stack, ((0, 0), (0, k2p - k2), (0, k2p - k2)))
        hx = jnp.pad(hx, ((0, 0), (0, k2p - k2)))
        fold = jnp.pad(fold, ((0, 0), (0, k1p - k1), (0, k2p - k2)))

    if not use_pallas:
        bpad = np.zeros((qp, el, k2), np.float32)
        bpad[:q] = beta
        return _batched_ref_jit(h_stack, jnp.asarray(bpad), fold, hx)[:q]

    bpad = np.zeros((el, qp, k2p), np.float32)
    bpad[:, :q, :k2] = np.swapaxes(beta, 0, 1)
    out = batched_weightings_pallas(h_stack, jnp.asarray(bpad), fold, hx,
                                    interpret=bool(interpret))
    return out[:q, :k1]
