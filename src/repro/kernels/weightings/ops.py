"""Jitted wrapper for the fused weightings kernel: pad + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.weightings.ref import fused_weightings_ref
from repro.kernels.weightings.weightings import fused_weightings_pallas


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


_ref_jit = jax.jit(fused_weightings_ref)


def fused_weightings(h_stack, beta, fold, hx, *, use_pallas: bool = True,
                     interpret: bool | None = None):
    """See ref.py for semantics. Pads K1/K2 to 128 multiples for the MXU.

    Padding is value-safe: padded H rows/cols and beta/hx entries are zero
    => p_row pads to 0; padded fold rows are zero => p1 pads to 0 and those
    1-D bins are sliced away.
    """
    h_stack = jnp.asarray(h_stack, jnp.float32)
    beta = jnp.asarray(beta, jnp.float32)
    fold = jnp.asarray(fold, jnp.float32)
    hx = jnp.asarray(hx, jnp.float32)
    if not use_pallas:
        return _ref_jit(h_stack, beta, fold, hx)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    el, k2, _ = h_stack.shape
    k1 = fold.shape[1]
    k2p = _round_up(k2, 128)
    k1p = _round_up(k1, 128)
    if (k2p, k1p) != (k2, k1):
        h_stack = jnp.pad(h_stack, ((0, 0), (0, k2p - k2), (0, k2p - k2)))
        beta = jnp.pad(beta, ((0, 0), (0, k2p - k2)))
        hx = jnp.pad(hx, ((0, 0), (0, k2p - k2)))
        fold = jnp.pad(fold, ((0, 0), (0, k1p - k1), (0, k2p - k2)))
    out = fused_weightings_pallas(h_stack, beta, fold, hx,
                                  interpret=bool(interpret))
    return out[:k1]
