"""Pallas-TPU kernel: fused multi-predicate weightings (§5.3, Eq. 28).

The paper's query path runs ~3 small ops per predicate (mat-vec, divide,
fold) plus a combine — at sub-ms latencies the launch/dispatch overhead
dominates. This kernel fuses the whole AND-chain:

    grid step l (one per predicate):
        v     = beta_l @ H_l^T        (1 x K2) @ (K2 x K2)   [MXU]
        p_row = clip(v / hx_l, 0, 1)                          [VPU]
        p1    = p_row @ fold_l^T      (1 x K2) @ (K1 x K2)^T  [MXU]
        acc  *= p1                    running product         [VPU]

One launch per query instead of ~3 ops x n_predicates. The accumulator
stays resident in VMEM across the whole grid; H/beta/hx/fold stream per
predicate. Everything is padded to 128-lane multiples by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, beta_ref, hx_ref, fold_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.ones_like(out_ref)

    hmat = h_ref[0]                        # (K2, K2)
    beta = beta_ref[0]                     # (1, K2)
    hx = hx_ref[0]                         # (1, K2)
    fold = fold_ref[0]                     # (K1, K2)
    v = jax.lax.dot_general(beta, hmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, K2)
    p_row = jnp.clip(v / jnp.maximum(hx, 1e-30), 0.0, 1.0)
    p1 = jax.lax.dot_general(p_row, fold, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (1, K1)
    out_ref[...] *= p1


def _batched_kernel(h_ref, beta_ref, hx_ref, fold_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.ones_like(out_ref)

    hmat = h_ref[0]                        # (K2, K2)
    beta = beta_ref[0]                     # (Q, K2)
    hx = hx_ref[0]                         # (1, K2)
    fold = fold_ref[0]                     # (K1, K2)
    v = jax.lax.dot_general(beta, hmat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, K2)
    p_row = jnp.clip(v / jnp.maximum(hx, 1e-30), 0.0, 1.0)
    p1 = jax.lax.dot_general(p_row, fold, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, K1)
    out_ref[...] *= p1


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_weightings_pallas(h_stack, beta, fold, hx, interpret: bool = True):
    """Query-batched variant: one launch for a whole plan-shape group.

    h_stack (L,K2,K2) f32, beta (L,Q,K2), fold (L,K1,K2), hx (L,K2).
    Returns (Q, K1): per-query prod_l fold_l(clip(H_l beta_ql / hx_l, 0, 1)).

    Same grid walk as the single-query kernel (one step per predicate), but
    the mat-vec becomes a (Q,K2)x(K2,K2) matmul — the MXU amortizes per-query
    dispatch exactly as the single-query kernel amortizes per-predicate ops.
    The (Q,K1) accumulator stays resident in VMEM across the grid.
    """
    el, k2, _ = h_stack.shape
    q = beta.shape[1]
    k1 = fold.shape[1]
    hx2 = hx[:, None, :]                   # (L, 1, K2)
    return pl.pallas_call(
        _batched_kernel,
        grid=(el,),
        in_specs=[
            pl.BlockSpec((1, k2, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, q, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, k1, k2), lambda l: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q, k1), lambda l: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, k1), jnp.float32),
        interpret=interpret,
    )(h_stack, beta, hx2, fold)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_weightings_pallas(h_stack, beta, fold, hx, interpret: bool = True):
    """h_stack (L,K2,K2) f32, beta (L,K2), fold (L,K1,K2), hx (L,K2).

    Returns prod_l fold_l(clip(H_l beta_l / hx_l, 0, 1)), shape (K1,).
    """
    el, k2, _ = h_stack.shape
    k1 = fold.shape[1]
    beta2 = beta[:, None, :]               # (L, 1, K2)
    hx2 = hx[:, None, :]                   # (L, 1, K2)
    prod = pl.pallas_call(
        _kernel,
        grid=(el,),
        in_specs=[
            pl.BlockSpec((1, k2, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, 1, k2), lambda l: (l, 0, 0)),
            pl.BlockSpec((1, k1, k2), lambda l: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k1), lambda l: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, k1), jnp.float32),
        interpret=interpret,
    )(h_stack, beta2, hx2, fold)
    return prod[0]
