"""Pallas-TPU kernels for the paper's two compute hot-spots (DESIGN.md §3):

  * ``hist2d`` — 2-D bin counting as one-hot matmuls on the MXU
    (construction);
  * ``weightings`` — fused multi-predicate H@beta -> fold -> Hadamard
    product chain (query execution: "a handful of small matmuls" fused
    into ONE kernel launch).

Each package: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py`` (jit
wrapper with padding, power-of-two launch bucketing and CPU-interpret
fallback), ``ref.py`` (pure-jnp oracle).
"""
